"""Post-hoc event replay over a compiled trace.

:func:`replay_events` re-runs the inlined scheduling recurrence of
:func:`repro.core.fastsim.run_segment` -- same statement order, same
float arithmetic, same token-bucket walk -- but records what the fast
backends deliberately discard: every load/store grant time (and how much
of it was bandwidth throttling) and every ``rasa_mm``'s WL/FF/FS/DR
sub-stage window.

This is the *only* way the telemetry subsystem observes instruction-level
time: the scanned loops (numpy and jax alike) carry no hooks, and the
replay consumes exactly the inputs a run already produced -- the
:class:`~repro.core.trace.CompiledTrace` and the
:class:`~repro.core.fastsim.StreamModelParams` holding the final share
schedule the arbiter settled on.  Replaying under the settled schedule
reproduces the run bit-for-bit (the same property the arbiter's
visible-schedule skip rule relies on), which
``tests/test_obs.py`` pins against the reference simulator's
``MMSchedule`` list and recorded grants.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.designs import EngineConfig
from ..core.fastsim import StreamModelParams
from ..core.isa import NUM_TREGS
from ..core.trace import OP_MM, OP_TL, OP_TS, CompiledTrace


@dataclasses.dataclass(frozen=True, eq=False)
class StreamEvents:
    """Per-instruction timing events of one simulated segment.

    Arrays are parallel within each group; ``*_index`` holds the stream
    position (instruction index) of each event.  All times are engine
    cycles relative to the segment's own t=0 (callers offset by the
    segment's start time when placing events on a chip timeline).
    """

    # -- tile loads: grant start, bandwidth-throttle delay, bytes moved
    tl_index: np.ndarray        # int64
    tl_start: np.ndarray        # float64
    tl_stall: np.ndarray        # float64 (start - port_start; 0 unthrottled)
    tl_bytes: np.ndarray        # float64
    # -- tile stores (free stores have stall 0 and start = data-ready)
    ts_index: np.ndarray        # int64
    ts_start: np.ndarray        # float64
    ts_stall: np.ndarray        # float64
    # -- rasa_mm sub-stage windows (wl_start == ff-chain entry for skips)
    mm_index: np.ndarray        # int64
    mm_skip: np.ndarray         # bool (WLBP weight-reload skip)
    mm_wl_start: np.ndarray     # float64
    mm_ff_start: np.ndarray     # float64
    mm_ff_end: np.ndarray       # float64
    mm_fs_end: np.ndarray       # float64
    mm_dr_end: np.ndarray       # float64
    #: replayed makespan -- must agree with the run's TimingResult.cycles
    cycles: float
    bw_stall: float
    wl_skips: int

    def __len__(self) -> int:
        return (len(self.tl_index) + len(self.ts_index)
                + len(self.mm_index))


def replay_events(trace: CompiledTrace, cfg: EngineConfig,
                  params: StreamModelParams) -> StreamEvents:
    """Replay ``trace`` under ``params`` and record every event.

    Mirrors ``run_segment`` statement for statement (the one behavioral
    addition: event capture).  ``params`` must be the exact settled
    schedule the run used -- for closed-batch chips that is
    ``CoreCluster.last_params[i]``, for online segments the span's
    ``_vis`` visible schedule.
    """
    wl = cfg.wl_cycles
    fs = cfg.fs_cycles
    dr = cfg.dr_cycles
    issue_per_cycle = cfg.core_issue_width * (cfg.core_clock_hz
                                              / cfg.engine_clock_hz)
    load_lat = float(cfg.load_latency)
    wlbp, wls, pipe = cfg.wlbp, cfg.wls, cfg.pipe

    port = params.is_port_model
    inv_load = 1.0 / params.load_ports
    store_free = params.store_ports is None
    inv_store = 1.0 / params.store_ports if not store_free else 0.0
    charge = params.charge_store_bytes and not port
    shares = list(params.shares)
    n_sh = len(shares)
    E = params.epoch_cycles
    sched_end = params.schedule_end
    tail = params.tail_share
    burst = params.burst_bytes
    tokens = burst
    bt = 0.0

    def grant(tokens, bt, t_earliest, n_bytes):
        # == fastsim.run_segment's inlined EpochBandwidthLoadModel._grant
        while bt < t_earliest:
            rate = shares[int(bt // E)] if bt // E < n_sh else tail
            if bt >= sched_end:
                step_end = t_earliest
            else:
                e_end = (int(bt // E) + 1) * E
                step_end = t_earliest if t_earliest < e_end else e_end
            if math.isinf(rate):
                tokens = burst
            else:
                tokens = tokens + rate * (step_end - bt)
                if tokens > burst:
                    tokens = burst
            bt = step_end
        need = n_bytes if n_bytes < burst else burst
        if tokens >= need:
            start = t_earliest
        else:
            t, tk = bt, tokens
            while True:
                rate = shares[int(t // E)] if t // E < n_sh else tail
                if math.isinf(rate):
                    start = t
                    break
                if rate <= 0.0 and t >= sched_end:
                    raise RuntimeError("tail share must be > 0: request can "
                                       "never be granted")
                e_end = (int(t // E) + 1) * E
                if rate > 0.0:
                    t_hit = t + (need - tk) / rate
                    if t_hit <= e_end or t >= sched_end:
                        start = t_hit
                        break
                    tk += rate * (e_end - t)
                t = e_end
            if start < t_earliest:
                start = t_earliest
        while bt < start:
            rate = shares[int(bt // E)] if bt // E < n_sh else tail
            if bt >= sched_end:
                step_end = start
            else:
                e_end = (int(bt // E) + 1) * E
                step_end = start if start < e_end else e_end
            if math.isinf(rate):
                tokens = burst
            else:
                tokens = tokens + rate * (step_end - bt)
                if tokens > burst:
                    tokens = burst
            bt = step_end
        return start, tokens - n_bytes, bt

    op = trace.opcode.tolist()
    rd = trace.r_dst.tolist()
    ra = trace.r_a.tolist()
    rb = trace.r_b.tolist()
    nb = trace.nbytes.tolist()
    tms = trace.tm.tolist()
    reus = trace.reusable.tolist()

    reg_ready = [0.0] * NUM_TREGS
    p_ff_start = -1.0
    p_ff_end = p_fs_end = p_dr_end = 0.0
    have_prev = False
    wl_port_free = 0.0
    t_end = 0.0
    wl_skips = 0
    bw_stall = 0.0
    next_free = store_next = 0.0

    ev_tl: list[tuple[int, float, float, float]] = []
    ev_ts: list[tuple[int, float, float]] = []
    ev_mm: list[tuple[int, bool, float, float, float, float, float]] = []

    for i in range(len(op)):
        o = op[i]
        t_issue = i / issue_per_cycle

        if o == OP_TL:
            port_start = t_issue if t_issue > next_free else next_free
            if port:
                start = port_start
                stall = 0.0
            else:
                start, tokens, bt = grant(tokens, bt, port_start, nb[i])
                stall = start - port_start
                bw_stall += stall
            next_free = start + inv_load
            done = start + load_lat
            reg_ready[rd[i]] = done
            if done > t_end:
                t_end = done
            ev_tl.append((i, start, stall, nb[i]))
            continue

        if o == OP_TS:
            r = reg_ready[ra[i]]
            t_avail = t_issue if t_issue > r else r
            if store_free:
                start = t_avail
                stall = 0.0
                e = t_avail + 1.0
            else:
                port_start = t_avail if t_avail > store_next else store_next
                if charge:
                    start, tokens, bt = grant(tokens, bt, port_start, nb[i])
                    stall = start - port_start
                    bw_stall += stall
                else:
                    start = port_start
                    stall = 0.0
                store_next = start + inv_store
                e = start + 1.0
            if e > t_end:
                t_end = e
            ev_ts.append((i, start, stall))
            continue

        if o != OP_MM:          # OP_NOP padding
            continue

        c, a, b = rd[i], ra[i], rb[i]
        t_ready_ac = max(t_issue, reg_ready[a], reg_ready[c])
        t_ready_b = max(t_issue, reg_ready[b])
        reuse = wlbp and reus[i]

        if reuse:
            # reference reports wl_start = t_ready_b for a skipped WL
            wl_start = t_ready_b
            ff_start = max(t_ready_ac, p_ff_end if have_prev else 0.0)
            wl_skips += 1
        elif wls:
            wl_start = max(t_ready_b, p_ff_start if have_prev else 0.0,
                           wl_port_free)
            hidden = have_prev and wl_start <= p_fs_end
            weights_ready = (wl_start + 1.0) if hidden else (wl_start + wl)
            ff_start = max(t_ready_ac, p_ff_end if have_prev else 0.0,
                           weights_ready)
            wl_port_free = wl_start + wl
        elif pipe:
            wl_start = max(t_ready_b, p_fs_end if have_prev else 0.0,
                           wl_port_free)
            ff_start = max(t_ready_ac, wl_start + wl,
                           p_dr_end if have_prev else 0.0)
            wl_port_free = wl_start + wl
        else:  # BASE
            wl_start = max(t_ready_b, p_dr_end if have_prev else 0.0,
                           wl_port_free)
            ff_start = max(t_ready_ac, wl_start + wl)
            wl_port_free = wl_start + wl

        ff_end = ff_start + tms[i]
        fs_end = ff_end + fs
        dr_end = fs_end + dr
        reg_ready[c] = dr_end
        if dr_end > t_end:
            t_end = dr_end
        p_ff_start, p_ff_end, p_fs_end, p_dr_end = (ff_start, ff_end,
                                                    fs_end, dr_end)
        have_prev = True
        ev_mm.append((i, reuse, wl_start, ff_start, ff_end, fs_end, dr_end))

    def cols(rows, j, dtype=np.float64):
        return np.array([r[j] for r in rows], dtype=dtype)

    return StreamEvents(
        tl_index=cols(ev_tl, 0, np.int64), tl_start=cols(ev_tl, 1),
        tl_stall=cols(ev_tl, 2), tl_bytes=cols(ev_tl, 3),
        ts_index=cols(ev_ts, 0, np.int64), ts_start=cols(ev_ts, 1),
        ts_stall=cols(ev_ts, 2),
        mm_index=cols(ev_mm, 0, np.int64), mm_skip=cols(ev_mm, 1, bool),
        mm_wl_start=cols(ev_mm, 2), mm_ff_start=cols(ev_mm, 3),
        mm_ff_end=cols(ev_mm, 4), mm_fs_end=cols(ev_mm, 5),
        mm_dr_end=cols(ev_mm, 6),
        cycles=float(t_end), bw_stall=float(bw_stall), wl_skips=wl_skips)
