"""Chip-level telemetry assembly.

Builders take a *finished* run -- the closed-batch
:class:`~repro.multicore.chip.CoreCluster` or an
:class:`~repro.multicore.online.OnlineChip` -- and assemble one
:class:`ChipTelemetry`: a :class:`SegmentTimeline` per (core, segment)
with start/finish on the shared chip clock, the bucket attribution, and
the arbiter's per-epoch share/occupancy traces.

Everything here is post-hoc.  The per-segment replay uses the exact
visible schedule each segment was last simulated under (the arbiter's
``Span._vis``, which the skip rules keep bit-faithful to the final
simulation), so stage events reproduce the run rather than a
re-derivation of it.  End-to-end bandwidth stalls are measured the way
``CoreCluster._contention_stalls`` defines them -- throttled makespan
minus unthrottled makespan -- and only segments whose arbiter actually
delayed an access are re-simulated.

Imports from :mod:`repro.multicore` stay inside functions: the chip
modules import :mod:`repro.obs.config` at module level, so this module
must not import them back at module level.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from ..core.trace import OP_MM, CompiledTrace, compile_stream
from .attribution import StallAttribution, attribute_segments
from .config import OFF, TelemetryConfig
from .record import StreamEvents, replay_events


@dataclasses.dataclass(frozen=True, eq=False)
class SegmentTimeline:
    """One scheduled unit of work, placed on the shared chip clock."""

    sid: int
    name: str
    core: int
    submit_time: float          # entered the queue (== start for closed)
    start_time: float           # core picked it up
    finish_time: float          # last event retired
    busy_cycles: float          # finish - start
    compute_cycles: float       # sum of FF feed rows (tm)
    #: end-to-end contention cost: throttled minus unthrottled makespan
    bw_stall_cycles: float
    #: raw arbiter grant delay (the pipeline may absorb it)
    arb_delay_cycles: float
    n_mm: int
    n_tl: int
    n_ts: int
    wl_skips: int
    #: per-instruction events (only with ``TelemetryConfig.stages``)
    events: StreamEvents | None = None
    #: busy cycles discarded by fault preemption -- nonzero only on the
    #: preempted instance of a segment cut by a ``core_down`` event
    fault_lost_cycles: float = 0.0

    @property
    def queue_cycles(self) -> float:
        return self.start_time - self.submit_time


@dataclasses.dataclass(frozen=True, eq=False)
class ChipTelemetry:
    """A finished run's full timeline (identity-hashed, not compared)."""

    kind: str                   # "closed" | "online"
    design: str
    n_cores: int
    epoch_cycles: float
    window: float               # run window on the chip clock
    segments: tuple[SegmentTimeline, ...]
    share_trace: tuple[float, ...]
    active_trace: tuple[int, ...]
    core_weights: tuple[float, ...]
    #: labeled instants (arrivals, admissions) for the exporters
    marks: tuple[tuple[float, str], ...]
    attribution: StallAttribution
    config: TelemetryConfig


def _trace_of(segment_trace: CompiledTrace | None,
              stream) -> CompiledTrace:
    if segment_trace is not None:
        return segment_trace
    if stream is None:
        raise ValueError("segment retained neither a compiled trace nor "
                         "an instruction stream -- was the run made with "
                         "telemetry enabled?")
    return compile_stream(stream)


def _compute_cycles(trace: CompiledTrace) -> float:
    return float(trace.tm[trace.opcode == OP_MM].sum())


def _check_replay(events: StreamEvents, cycles: float, what: str) -> None:
    if not math.isclose(events.cycles, cycles, rel_tol=1e-6, abs_tol=1e-6):
        raise RuntimeError(
            f"telemetry replay diverged from the run on {what}: "
            f"{events.cycles} != {cycles} -- the retained schedule does "
            f"not match the one the run used")


def _attribution_rows(segments: Sequence[SegmentTimeline]):
    return [(s.core, s.submit_time, s.start_time, s.finish_time,
             s.compute_cycles, s.bw_stall_cycles, s.fault_lost_cycles)
            for s in segments]


def build_chip_telemetry(cluster, shards, report,
                         tcfg: TelemetryConfig = OFF) -> ChipTelemetry:
    """Assemble telemetry for a finished closed-batch cluster run.

    ``cluster`` must have run (``CoreCluster.run_streams`` records the
    results, end-to-end stalls and the settled per-core stream-model
    parameters); ``shards``/``report`` are the partition and the
    aggregate the entry point already built.
    """
    chip = cluster.chip
    segments = []
    for i, res in enumerate(cluster.last_results):
        engine = chip.core_specs[i].engine
        name = "+".join(report.per_core_gemms[i]) \
            if i < len(report.per_core_gemms) else f"core{i}"
        trace = None
        events = None
        compute = 0.0
        if res.n_mm:
            trace = _trace_of(
                cluster.last_traces[i] if cluster.last_traces else None,
                cluster.last_streams[i] if cluster.last_streams else None)
            compute = _compute_cycles(trace)
        if tcfg.stages and trace is not None:
            events = replay_events(trace, engine, cluster.last_params[i])
            _check_replay(events, res.cycles, f"core {i}")
        segments.append(SegmentTimeline(
            sid=i, name=name or f"core{i}", core=i,
            submit_time=0.0, start_time=0.0, finish_time=res.cycles,
            busy_cycles=res.cycles, compute_cycles=compute,
            bw_stall_cycles=cluster.last_stalls[i],
            arb_delay_cycles=res.bw_stall_cycles,
            n_mm=res.n_mm, n_tl=res.n_tl, n_ts=res.n_ts,
            wl_skips=res.wl_skips, events=events))
    segs = tuple(segments)
    return ChipTelemetry(
        kind="closed", design=report.design, n_cores=chip.n_cores,
        epoch_cycles=report.epoch_cycles, window=report.cycles,
        segments=segs, share_trace=report.share_trace,
        active_trace=report.active_trace,
        core_weights=report.core_weights, marks=(),
        attribution=attribute_segments(chip.n_cores, report.cycles,
                                       _attribution_rows(segs)),
        config=tcfg)


def build_online_telemetry(online, tcfg: TelemetryConfig = OFF,
                           names: Mapping[int, str] | None = None,
                           marks: Sequence[tuple[float, str]] = ()
                           ) -> ChipTelemetry:
    """Assemble telemetry for a finished :class:`OnlineChip` run.

    The chip must have been constructed with ``telemetry`` enabled (so
    retired segments keep their traces) and be drained.  ``names`` maps
    segment sid -> display name (the serving batcher passes request
    names); ``marks`` are labeled instants (cycles, label).
    """
    from ..core.fastsim import run_segment
    from ..multicore.chip import stream_model_params

    chip = online.chip
    E = chip.epoch_cycles
    names = names or {}
    # keyed by the trace *object* (identity-hashed): keying by id() would
    # let a freed trace's address be reused by a later compile_stream and
    # alias two different segments onto one cache entry
    unthrottled_cycles: dict[tuple[CompiledTrace, str], float] = {}
    segments = []
    for seg in online.history:
        if seg.result is None or seg.span is None:
            continue            # never started (undrained run)
        engine = chip.core_specs[seg.core].engine
        busy = seg.result.cycles
        start = seg.span.start * E
        name = names.get(seg.sid, "+".join(s.name for s in seg.specs
                                           if s.name) or f"seg{seg.sid}")
        if seg.preempted_at is not None:
            # a preempted instance: busy to the fault boundary, credited
            # with its kept prefix; the rest of the interval is lost work.
            # No unthrottled counterfactual or stage replay exists for the
            # cut -- its remainder is a later instance of its own.
            segments.append(SegmentTimeline(
                sid=seg.sid, name=f"{name} (preempted)", core=seg.core,
                submit_time=seg.submit_epoch * E, start_time=start,
                finish_time=start + busy, busy_cycles=busy,
                compute_cycles=seg.kept_compute, bw_stall_cycles=0.0,
                arb_delay_cycles=0.0, n_mm=seg.result.n_mm,
                n_tl=seg.result.n_tl, n_ts=seg.result.n_ts,
                wl_skips=seg.result.wl_skips, events=None,
                fault_lost_cycles=max(0.0, busy - seg.kept_compute)))
            continue
        trace = _trace_of(seg.trace, seg.stream)
        compute = _compute_cycles(trace) / seg.speed
        arb_delay = seg.result.bw_stall_cycles
        bw_stall = 0.0
        if arb_delay != 0.0:
            key = (trace, engine.name)
            base = unthrottled_cycles.get(key)
            if base is None:
                base = run_segment(
                    trace, engine,
                    stream_model_params(chip, engine))[0].cycles
                unthrottled_cycles[key] = base
            # clamp: cross-backend rounding must not push fill/drain
            # negative (reference results vs. the numpy baseline)
            bw_stall = min(max(0.0, busy - base / seg.speed),
                           max(0.0, busy - compute))
        events = None
        if tcfg.stages and seg.speed == 1.0:
            # slowed cores run in a dilated local time base the replay
            # does not model; their timelines carry no stage events
            vis = seg.span._vis
            prefix, tail = vis if vis is not None else ((), math.inf)
            events = replay_events(
                trace, engine,
                stream_model_params(chip, engine, prefix, E, tail))
            _check_replay(events, busy, f"segment {seg.sid}")
        segments.append(SegmentTimeline(
            sid=seg.sid, name=name,
            core=seg.core, submit_time=seg.submit_epoch * E,
            start_time=start, finish_time=start + busy,
            busy_cycles=busy, compute_cycles=compute,
            bw_stall_cycles=bw_stall, arb_delay_cycles=arb_delay,
            n_mm=seg.result.n_mm, n_tl=seg.result.n_tl,
            n_ts=seg.result.n_ts, wl_skips=seg.result.wl_skips,
            events=events))
    segs = tuple(sorted(segments, key=lambda s: (s.core, s.start_time)))
    window = max((s.finish_time for s in segs), default=0.0)
    fault_marks = tuple((ep * E, label) for ep, label in online.fault_log)
    return ChipTelemetry(
        kind="online", design=chip.design_name, n_cores=chip.n_cores,
        epoch_cycles=E, window=window, segments=segs,
        share_trace=online.share_trace, active_trace=online.active_trace,
        core_weights=(1.0,) * chip.n_cores,
        marks=tuple(sorted(tuple(marks) + fault_marks)),
        attribution=attribute_segments(chip.n_cores, window,
                                       _attribution_rows(segs)),
        config=tcfg)
