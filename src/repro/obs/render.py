"""Plain-text timeline renderer (docs, tests, CLI output).

One bar per core over the run window: ``#`` busy, ``-`` queued work
waiting on the core, ``.`` idle.  Below the bars, the stall-attribution
table.  Deliberately dependency-free so benchmark scripts can print it.
"""

from __future__ import annotations

from .timeline import ChipTelemetry


def render_timeline(tele: ChipTelemetry, width: int = 72) -> str:
    """ASCII chip timeline + attribution table."""
    window = tele.window
    lines = [f"{tele.design} [{tele.kind}] {tele.n_cores} cores, "
             f"window {window:.0f} cycles "
             f"({'1 char = %.0f cyc' % (window / width) if window else ''})"]
    if window <= 0:
        return lines[0]
    scale = width / window

    def col(t: float) -> int:
        return min(width - 1, max(0, int(t * scale)))

    for c in range(tele.n_cores):
        row = ["."] * width
        for s in tele.segments:
            if s.core != c:
                continue
            if s.start_time > s.submit_time:
                for k in range(col(s.submit_time), col(s.start_time) + 1):
                    if row[k] == ".":
                        row[k] = "-"
            for k in range(col(s.start_time), col(s.finish_time) + 1):
                row[k] = "#"
        lines.append(f"core {c:>2} |{''.join(row)}|")
    lines.append("        (# busy  - queued  . idle)")
    lines.append("")
    lines.append(tele.attribution.table())
    return "\n".join(lines)
