"""Chrome ``trace_event`` JSON export (Perfetto-viewable).

One process per chip, one thread track per core (plus a queue track and,
with stage events recorded, a stage track per core).  Counter tracks
carry the arbiter's per-epoch share and the in-flight core count.

Timestamps are engine cycles mapped 1:1 onto the format's microsecond
unit -- read "1 us" in the viewer as "1 cycle".  Load the file at
https://ui.perfetto.dev (or ``chrome://tracing``) via "Open trace file".
"""

from __future__ import annotations

import json
from pathlib import Path

from .timeline import ChipTelemetry

#: tid layout: per-core tracks at fixed offsets so mixed exports diff
#: cleanly.  Core run track = core index; the rest are offset blocks.
QUEUE_TID = 1000
STAGE_TID = 2000
MEM_TID = 3000


def _meta(pid: int, tid: int, name: str, sort: int) -> list[dict]:
    return [
        {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
         "args": {"name": name}},
        {"ph": "M", "name": "thread_sort_index", "pid": pid, "tid": tid,
         "args": {"sort_index": sort}},
    ]


def to_trace_events(tele: ChipTelemetry) -> dict:
    """Render telemetry as a ``trace_event`` JSON document (dict form)."""
    pid = 0
    ev: list[dict] = [
        {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
         "args": {"name": f"rasa-chip {tele.design} [{tele.kind}]"}},
    ]
    used_queue = any(s.start_time > s.submit_time for s in tele.segments)
    has_stages = any(s.events is not None for s in tele.segments)
    for c in range(tele.n_cores):
        ev += _meta(pid, c, f"core {c}", 10 * c)
        if used_queue:
            ev += _meta(pid, QUEUE_TID + c, f"core {c} queue", 10 * c + 1)
        if has_stages:
            ev += _meta(pid, STAGE_TID + c, f"core {c} stages", 10 * c + 2)
            ev += _meta(pid, MEM_TID + c, f"core {c} mem", 10 * c + 3)

    # -- run + queue slices, async request lifetimes ----------------------
    for s in tele.segments:
        args = {"sid": s.sid, "compute_cycles": s.compute_cycles,
                "bw_stall_cycles": s.bw_stall_cycles,
                "arb_delay_cycles": s.arb_delay_cycles,
                "queue_cycles": s.queue_cycles,
                "n_mm": s.n_mm, "n_tl": s.n_tl, "n_ts": s.n_ts,
                "wl_skips": s.wl_skips}
        if s.fault_lost_cycles:
            # keyed in only on preempted instances: fault-free exports
            # stay byte-identical to the pre-fault schema
            args["fault_lost_cycles"] = s.fault_lost_cycles
        ev.append({
            "ph": "X", "name": s.name, "cat": "segment", "pid": pid,
            "tid": s.core, "ts": s.start_time, "dur": s.busy_cycles,
            "args": args})
        if s.start_time > s.submit_time:
            ev.append({
                "ph": "X", "name": f"queued {s.name}", "cat": "queue",
                "pid": pid, "tid": QUEUE_TID + s.core,
                "ts": s.submit_time, "dur": s.start_time - s.submit_time,
                "args": {"sid": s.sid}})
        if tele.kind == "online":
            ev.append({"ph": "b", "cat": "request", "id": s.sid,
                       "name": s.name, "pid": pid, "tid": s.core,
                       "ts": s.submit_time, "args": {}})
            ev.append({"ph": "e", "cat": "request", "id": s.sid,
                       "name": s.name, "pid": pid, "tid": s.core,
                       "ts": s.finish_time, "args": {}})

    # -- per-instruction stage events (capped) ----------------------------
    budget = tele.config.max_stage_events
    dropped = 0

    def stage(items):
        nonlocal budget, dropped
        for e in items:
            if budget <= 0:
                dropped += 1
                continue
            budget -= 1
            ev.append(e)

    for s in tele.segments:
        if s.events is None:
            continue
        t0 = s.start_time
        evs = s.events
        tid = STAGE_TID + s.core
        for k in range(len(evs.mm_index)):
            wl0 = float(evs.mm_wl_start[k])
            ff0 = float(evs.mm_ff_start[k])
            ff1 = float(evs.mm_ff_end[k])
            fs1 = float(evs.mm_fs_end[k])
            dr1 = float(evs.mm_dr_end[k])
            items = []
            if not bool(evs.mm_skip[k]) and ff0 > wl0:
                items.append({"ph": "X", "name": "WL", "cat": "stage",
                              "pid": pid, "tid": tid, "ts": t0 + wl0,
                              "dur": ff0 - wl0})
            items.append({"ph": "X", "name": "FF", "cat": "stage",
                          "pid": pid, "tid": tid, "ts": t0 + ff0,
                          "dur": ff1 - ff0})
            if fs1 > ff1:
                items.append({"ph": "X", "name": "FS", "cat": "stage",
                              "pid": pid, "tid": tid, "ts": t0 + ff1,
                              "dur": fs1 - ff1})
            if dr1 > fs1:
                items.append({"ph": "X", "name": "DR", "cat": "stage",
                              "pid": pid, "tid": tid, "ts": t0 + fs1,
                              "dur": dr1 - fs1})
            stage(items)
        mtid = MEM_TID + s.core
        for k in range(len(evs.tl_index)):
            start = float(evs.tl_start[k])
            stall = float(evs.tl_stall[k])
            items = [{"ph": "X", "name": "TL", "cat": "mem", "pid": pid,
                      "tid": mtid, "ts": t0 + start, "dur": 1.0,
                      "args": {"bytes": float(evs.tl_bytes[k])}}]
            if stall > 0.0:
                items.insert(0, {
                    "ph": "X", "name": "bw-throttle", "cat": "stall",
                    "pid": pid, "tid": mtid, "ts": t0 + start - stall,
                    "dur": stall})
            stage(items)
        for k in range(len(evs.ts_index)):
            stall = float(evs.ts_stall[k])
            start = float(evs.ts_start[k])
            items = [{"ph": "X", "name": "TS", "cat": "mem", "pid": pid,
                      "tid": mtid, "ts": t0 + start, "dur": 1.0}]
            if stall > 0.0:
                items.insert(0, {
                    "ph": "X", "name": "bw-throttle", "cat": "stall",
                    "pid": pid, "tid": mtid, "ts": t0 + start - stall,
                    "dur": stall})
            stage(items)

    # -- counter tracks ---------------------------------------------------
    if tele.config.counters and tele.epoch_cycles > 0:
        E = tele.epoch_cycles
        for e, share in enumerate(tele.share_trace):
            ev.append({"ph": "C", "name": "bw share (B/cyc/weight)",
                       "pid": pid, "tid": 0, "ts": e * E,
                       "args": {"share": share}})
        for e, n in enumerate(tele.active_trace):
            ev.append({"ph": "C", "name": "active cores", "pid": pid,
                       "tid": 0, "ts": e * E, "args": {"active": n}})

    # -- labeled instants (arrivals, admissions) --------------------------
    for t, label in tele.marks:
        ev.append({"ph": "i", "name": label, "cat": "mark", "pid": pid,
                   "tid": 0, "ts": t, "s": "p"})

    out = {
        "traceEvents": ev,
        "displayTimeUnit": "ms",
        "otherData": {
            "schema": "rasa-trace/1",
            "time_unit": "1 us == 1 engine cycle",
            "design": tele.design, "kind": tele.kind,
            "n_cores": tele.n_cores, "window_cycles": tele.window,
            "attribution": {
                b: tele.attribution.total(b)
                for b in ("compute", "fill_drain", "bw_stall",
                          "queue_wait", "idle")},
        },
    }
    fault_lost = tele.attribution.total("fault_lost")
    if fault_lost:
        out["otherData"]["attribution"]["fault_lost"] = fault_lost
    if dropped:
        out["otherData"]["stage_events_dropped"] = dropped
    return out


def write_trace(tele: ChipTelemetry, path: str | Path) -> Path:
    """Write the Perfetto-loadable trace JSON; returns the path."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(to_trace_events(tele), indent=1,
                               sort_keys=True))
    return path
