"""Telemetry opt-in configuration.

This module deliberately imports nothing from the simulator layers so
that ``core``/``multicore``/``serving`` modules can take a
:class:`TelemetryConfig` parameter without an import cycle.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """What the run records; the default records nothing.

    ``enabled=False`` is the zero-cost path: runs carry no telemetry
    state, reports get ``telemetry=None``, and the simulation loops are
    byte-for-byte the same code path as before the subsystem existed.

    With ``enabled=True`` the chip/batch drivers retain enough of each
    finished run (compiled traces, the exact share-schedule parameters
    each segment was simulated under) to assemble a
    :class:`repro.obs.timeline.ChipTelemetry` after the fact.
    """

    enabled: bool = False
    #: also replay per-instruction stage events (TL/TS grants, MM
    #: FF/FS/DR windows) for every segment -- needed for stage tracks in
    #: the Perfetto export, costs one extra numpy replay per segment.
    stages: bool = False
    #: emit counter tracks (per-epoch bandwidth share, in-flight cores)
    #: in the exporters.
    counters: bool = True
    #: cap on stage events exported per trace file (a multi-million
    #: instruction run would otherwise produce an unloadable JSON).
    max_stage_events: int = 200_000

    def __post_init__(self):
        if self.max_stage_events < 0:
            raise ValueError("max_stage_events must be >= 0")


#: the shared "telemetry off" default (frozen, so safe to share).
OFF = TelemetryConfig()
