"""Fault-tolerant checkpoint store.

Properties needed at 1000-node scale, all implemented here:
  * atomic      -- write to <dir>.tmp-<uuid>, fsync, rename; a crashed save
                   never corrupts the latest checkpoint;
  * async       -- device->host transfer happens synchronously (cheap), the
                   file write runs on a background thread so the train loop
                   overlaps step N+1 with persisting step N;
  * resharding  -- restore() takes target shardings; a checkpoint written on
                   a (2,16,16) mesh restores onto (16,16) or a 1-device CPU
                   mesh (elastic restart after node loss);
  * integrity   -- per-leaf crc32 in the manifest, verified on load;
  * retention   -- keep the newest K checkpoints (never deleting the one
                   being written).

Format: one .npz per checkpoint (host-gathered leaves) + manifest.json.
On real multi-host pods each host would write only its address-space slice;
the single-process container gathers fully -- the interface (save/restore
via shardings) is the multi-host one.
"""

from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
import threading
import uuid
import zlib
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(directory: str | Path, step: int, state: Any) -> Path:
    """Synchronous atomic save; returns the final checkpoint dir."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp-{uuid.uuid4().hex[:8]}"
    tmp.mkdir(parents=True, exist_ok=True)
    try:
        names, leaves, _ = _flatten_with_names(state)
        arrays = {}
        manifest = {"step": int(step), "leaves": []}
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            arr = np.asarray(jax.device_get(leaf))
            key = f"leaf_{i}"
            # npz can't round-trip ml_dtypes (bfloat16 etc.): store raw
            # bytes; the logical dtype lives in the manifest.
            raw = np.frombuffer(
                np.ascontiguousarray(arr).tobytes(), np.uint8)
            arrays[key] = raw
            manifest["leaves"].append({
                "name": name, "key": key, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(raw.tobytes()),
            })
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        with open(tmp / "manifest.json", "r+b") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in directory.iterdir()
                   if p.name.startswith("step_"))
    return steps[-1] if steps else None


def restore_checkpoint(directory: str | Path, template: Any,
                       step: int | None = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore into `template`'s structure; `shardings` (optional pytree of
    NamedSharding) reshard onto the CURRENT mesh -- which may differ from
    the mesh that wrote the checkpoint (elastic restart)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")

    names, leaves, treedef = _flatten_with_names(template)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    out = []
    flat_shardings = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(leaves))
    if shardings is not None and len(flat_shardings) != len(leaves):
        flat_shardings = [None] * len(leaves)
    import ml_dtypes  # noqa: F401  (registers bfloat16 & friends)
    for name, leaf, sh in zip(names, leaves, flat_shardings):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        e = by_name[name]
        raw = data[e["key"]]
        if zlib.crc32(raw.tobytes()) != e["crc32"]:
            raise IOError(f"checksum mismatch for {name} (corrupt checkpoint)")
        arr = np.frombuffer(raw.tobytes(), np.dtype(e["dtype"])).reshape(
            e["shape"])
        want_shape = tuple(np.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != template {want_shape}")
        want_dtype = np.dtype(jax.numpy.result_type(leaf))
        if arr.dtype != want_dtype:
            arr = arr.astype(want_dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class CheckpointManager:
    """Async save + retention."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="ckpt")
        self._pending: concurrent.futures.Future | None = None
        self._lock = threading.Lock()

    def save_async(self, step: int, state: Any) -> None:
        """Device->host transfer now; file IO on the background thread."""
        names, leaves, treedef = _flatten_with_names(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        host_state = jax.tree_util.tree_unflatten(treedef, host_leaves)
        self.wait()
        self._pending = self._pool.submit(self._save_and_gc, step, host_state)

    def _save_and_gc(self, step: int, state: Any) -> None:
        save_checkpoint(self.directory, step, state)
        self._gc()

    def _gc(self) -> None:
        with self._lock:
            steps = sorted(int(p.name.split("_")[1])
                           for p in self.directory.iterdir()
                           if p.name.startswith("step_"))
            for s in steps[:-self.keep]:
                shutil.rmtree(self.directory / f"step_{s:08d}",
                              ignore_errors=True)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def restore_latest(self, template: Any, shardings: Any = None):
        return restore_checkpoint(self.directory, template,
                                  shardings=shardings)
