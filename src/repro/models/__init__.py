"""Model zoo + the uniform ModelApi used by training/serving/launch.

Families: dense / moe / vlm / audio (transformer.py), ssm / hybrid
(ssm_lm.py).  All GEMMs route through the configurable matrix engine
(`repro.models.common.matmul`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..config import ModelConfig, RunConfig
from . import ssm_lm, transformer

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelApi:
    """Functional model interface (params are explicit pytrees)."""
    cfg: RunConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], tuple]
    prefill: Callable
    decode_step: Callable
    init_decode_state: Callable

    @property
    def model(self) -> ModelConfig:
        return self.cfg.model


def build_model(cfg: RunConfig) -> ModelApi:
    m, e, p = cfg.model, cfg.engine, cfg.parallel
    if m.family in _TRANSFORMER_FAMILIES:
        mod = transformer
    elif m.family in ("ssm", "hybrid"):
        mod = ssm_lm
    else:
        raise ValueError(f"unknown family {m.family!r}")

    return ModelApi(
        cfg=cfg,
        init=lambda rng: mod.init_params(m, rng),
        loss=lambda params, batch: mod.loss_fn(params, batch, m, e, p),
        prefill=lambda params, tokens, state: mod.prefill(
            params, tokens, m, e, p, state),
        decode_step=lambda params, token, state: mod.decode_step(
            params, token, m, e, p, state),
        init_decode_state=lambda batch, max_seq, dtype=None:
            mod.init_decode_state(m, batch, max_seq, dtype),
    )
