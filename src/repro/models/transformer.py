"""Decoder-only transformer backbone: dense / MoE / VLM / audio families.

Parameters are stacked over layers ([L, ...] leading dim) and the forward
pass scans over them with a configurable remat policy -- this keeps the HLO
size O(1) in depth (essential for the 80-layer dry-runs) and is the
standard production pattern (MaxText-style).

Families:
  dense          -- plain GQA decoder (nemotron / qwen3 / gemma)
  moe            -- GQA decoder + top-k MoE FFN (grok / granite)
  vlm            -- dense + stub patch-embedding frontend, M-RoPE (qwen2-vl)
  audio          -- dense over summed EnCodec codebook embeddings with one
                    lm head per codebook (musicgen)
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..config import EngineConfig, ModelConfig, ParallelConfig
from ..distributed.sharding import constrain
from .common import (KeyGen, chunked_cross_entropy, cross_entropy,
                     embed_init, he_init, matmul)
from .layers import (KVCache, attention_block, mlp_block, rms_norm,
                     rope_angles)
from .moe import moe_block

def mrope_sections(head_dim: int) -> tuple[int, int, int]:
    """Temporal/height/width frequency splits, proportioned like qwen2-vl
    (16/24/24 of the 64 half-dims at head_dim=128)."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


# ------------------------------------------------------------------- params

def init_layer_params(cfg: ModelConfig, kg: KeyGen, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    L = cfg.n_layers
    p = {
        "norm1": jnp.zeros((L, d), dtype),
        "wq": he_init(kg("wq"), (L, d, cfg.n_heads * hd), dtype, fan_in=d),
        "wk": he_init(kg("wk"), (L, d, cfg.n_kv_heads * hd), dtype, fan_in=d),
        "wv": he_init(kg("wv"), (L, d, cfg.n_kv_heads * hd), dtype, fan_in=d),
        "wo": he_init(kg("wo"), (L, cfg.n_heads * hd, d), dtype,
                      fan_in=cfg.n_heads * hd),
        "norm2": jnp.zeros((L, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((L, hd), dtype)
        p["k_norm"] = jnp.zeros((L, hd), dtype)
    if cfg.moe is not None:
        e, fe = cfg.moe.n_experts, cfg.moe.d_ff_expert
        p["router"] = he_init(kg("router"), (L, d, e), dtype, fan_in=d)
        if cfg.fuse_gate_up:
            # [L, E, D, 2, Fe]: gate/up axis unsharded (cf. w_gate_up)
            p["experts_w_gate_up"] = he_init(kg("ewgu"), (L, e, d, 2, fe),
                                             dtype, fan_in=d)
        else:
            p["experts_w_gate"] = he_init(kg("ewg"), (L, e, d, fe), dtype,
                                          fan_in=d)
            p["experts_w_up"] = he_init(kg("ewu"), (L, e, d, fe), dtype,
                                        fan_in=d)
        p["experts_w_down"] = he_init(kg("ewd"), (L, e, fe, d), dtype, fan_in=fe)
    else:
        f = cfg.d_ff
        gated = cfg.act in ("swiglu", "geglu")
        if gated and cfg.fuse_gate_up:
            # [L, D, 2, F]: the 2 (gate/up) axis is unsharded, so the
            # post-GEMM split never reshards the model-sharded F dim
            p["w_gate_up"] = he_init(kg("wgu"), (L, d, 2, f), dtype, fan_in=d)
        else:
            if gated:
                p["w_gate"] = he_init(kg("wg"), (L, d, f), dtype, fan_in=d)
            p["w_up"] = he_init(kg("wu"), (L, d, f), dtype, fan_in=d)
        p["w_down"] = he_init(kg("wd"), (L, f, d), dtype, fan_in=f)
    return p


def init_params(cfg: ModelConfig, rng: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    kg = KeyGen(rng)
    d = cfg.d_model
    vocab_in = cfg.vocab * (cfg.n_codebooks if cfg.family == "audio" else 1)
    params = {
        "embedding": embed_init(kg("embed"), (vocab_in, d), dtype),
        "layers": init_layer_params(cfg, kg, dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(
            kg("head"), (d, cfg.vocab * cfg.n_codebooks), dtype, fan_in=d)
    if cfg.frontend == "vision":
        # stub patch projection: precomputed patch features -> d_model
        params["patch_proj"] = he_init(kg("patch"), (d, d), dtype, fan_in=d)
    return params


# ------------------------------------------------------------------ blocks

def decoder_block(params_l: dict, x: jax.Array, cfg: ModelConfig,
                  engine: EngineConfig, sin, cos,
                  cache: Optional[KVCache] = None):
    """Pre-norm block; returns (x, new_cache, aux_loss)."""
    h = rms_norm(x, params_l["norm1"], cfg.rms_eps)
    attn_out, new_cache = attention_block(params_l, h, cfg, engine, sin, cos,
                                          cache)
    x = constrain(x + attn_out, "btd")
    h = rms_norm(x, params_l["norm2"], cfg.rms_eps)
    if cfg.moe is not None:
        ffn_out, aux = moe_block(params_l, h, cfg, engine)
    else:
        ffn_out, aux = mlp_block(params_l, h, cfg, engine), 0.0
    x = constrain(x + ffn_out, "btd")
    return x, new_cache, aux


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


def run_layers(params: dict, x: jax.Array, cfg: ModelConfig,
               engine: EngineConfig, sin, cos, remat: str = "full",
               caches: Optional[KVCache] = None, scan: bool = True):
    """Scan the decoder stack.  caches: stacked KVCache ([L, ...] leaves) for
    decode, or None for train/prefill.  scan=False unrolls a python loop
    (reduced-depth roofline compiles -- cost_analysis counts a scan body
    once, so totals need an unrolled artifact)."""
    aux0 = jnp.zeros((), jnp.float32)

    if not scan:
        aux = aux0
        new_caches = []
        for i in range(cfg.n_layers):
            params_l = jax.tree.map(lambda a: a[i], params["layers"])
            cache_l = (jax.tree.map(lambda a: a[i], caches)
                       if caches is not None else None)
            x, nc, aux_l = decoder_block(params_l, x, cfg, engine, sin, cos,
                                         cache_l)
            aux = aux + aux_l
            if caches is not None:
                new_caches.append(nc)
        if caches is None:
            return x, None, aux
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches) \
            if new_caches else caches
        return x, stacked, aux

    if caches is None:
        def body(carry, params_l):
            h, aux = carry
            h, _, aux_l = decoder_block(params_l, h, cfg, engine, sin, cos)
            return (h, aux + aux_l), None
        (x, aux), _ = jax.lax.scan(_remat(body, remat), (x, aux0),
                                   params["layers"])
        return x, None, aux

    def body(carry, layer_in):
        params_l, cache_l = layer_in
        h, aux = carry
        h, new_cache, aux_l = decoder_block(params_l, h, cfg, engine,
                                            sin, cos, cache_l)
        return (h, aux + aux_l), new_cache

    (x, aux), new_caches = jax.lax.scan(body, (x, aux0),
                                        (params["layers"], caches))
    return x, new_caches, aux


# ---------------------------------------------------------------- embedding

def embed_tokens(params: dict, cfg: ModelConfig, tokens: jax.Array,
                 patch_embeds: jax.Array | None = None) -> jax.Array:
    """tokens: [B, S] (or [B, S, n_codebooks] for audio).  For the vlm
    family, `patch_embeds` [B, P, D] (stub frontend output) is prepended."""
    emb = params["embedding"]
    if cfg.family == "audio":
        # sum the per-codebook embeddings (offsets into one stacked table)
        offsets = jnp.arange(cfg.n_codebooks) * cfg.vocab
        x = emb[(tokens + offsets[None, None, :]).reshape(tokens.shape[0], -1)]
        x = x.reshape(*tokens.shape, cfg.d_model).sum(axis=2)
    else:
        x = emb[tokens]
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = matmul(patch_embeds.astype(x.dtype), params["patch_proj"])
        x = jnp.concatenate([pe, x], axis=1)
    return x


def positions_for(cfg: ModelConfig, batch: int, seq: int,
                  offset: jax.Array | int = 0) -> jax.Array:
    pos = jnp.arange(seq)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope == "mrope":
        # stub M-RoPE positions: text tokens use t == h == w (the qwen2-vl
        # convention); real image grids would vary h/w per patch.
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


def logits_from(params: dict, cfg: ModelConfig, x: jax.Array,
                engine: EngineConfig) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = matmul(x, params["embedding"].T, engine, out_dtype=jnp.float32)
    else:
        logits = matmul(x, params["lm_head"], engine, out_dtype=jnp.float32)
    if cfg.family == "audio":
        b, s, _ = logits.shape
        return logits.reshape(b, s, cfg.n_codebooks, cfg.vocab)
    return logits


# ------------------------------------------------------------------- losses

def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            engine: EngineConfig, parallel: ParallelConfig):
    """batch: tokens [B,S] (+ labels [B,S]; audio: [B,S,cb];
    vlm: + patch_embeds [B,P,Dp])."""
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens, batch.get("patch_embeds"))
    x = constrain(x, "btd")
    b, s = x.shape[0], x.shape[1]
    pos = positions_for(cfg, b, s)
    sin, cos = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta,
                           mrope_sections(cfg.resolved_head_dim) if cfg.rope == "mrope" else None)
    x, _, aux = run_layers(params, x, cfg, engine, sin, cos,
                           remat=parallel.remat, scan=parallel.scan_layers)
    labels = batch["labels"]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        # patch positions carry no next-token loss
        pad = jnp.full(
            (b, batch["patch_embeds"].shape[1]) + labels.shape[2:], -100,
            labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)

    # chunked CE: never materializes [B, S, V] logits (common.py)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head_w = (params["embedding"].T if cfg.tie_embeddings
              else params["lm_head"])
    logits_fn = None
    if cfg.family == "audio":
        logits_fn = lambda lg: lg.reshape(
            *lg.shape[:-1], cfg.n_codebooks, cfg.vocab)
    ce, n_valid = chunked_cross_entropy(x, head_w, labels,
                                        chunk=engine.ce_chunk,
                                        logits_fn=logits_fn)
    loss = ce + aux
    return loss, {"ce": ce, "aux_loss": aux, "n_valid": n_valid}


# ------------------------------------------------------------------ serving

class DecodeState(NamedTuple):
    caches: KVCache            # stacked [L, ...] leaves
    position: jax.Array        # [B] next position (uniform here)


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=None) -> DecodeState:
    from ..distributed.sharding import current_ctx, kv_cache_spec
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_seq, hd)
    k = jnp.zeros(shape, dtype)
    v = jnp.zeros(shape, dtype)
    caches = KVCache(k=k, v=v, length=jnp.zeros((cfg.n_layers,), jnp.int32))
    return DecodeState(caches=caches, position=jnp.zeros((), jnp.int32))


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig,
            engine: EngineConfig, parallel: ParallelConfig,
            state: DecodeState) -> tuple[jax.Array, DecodeState]:
    """Run the prompt through the stack, filling the caches; returns logits
    of the last position and the updated state."""
    b, s = tokens.shape[0], tokens.shape[1]
    x = embed_tokens(params, cfg, tokens)
    pos = positions_for(cfg, b, s)
    sin, cos = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta,
                           mrope_sections(cfg.resolved_head_dim) if cfg.rope == "mrope" else None)

    # caches at length 0: attention_block's decode path writes k/v at [0, s)
    x, new_caches, _ = run_layers(params, x, cfg, engine, sin, cos,
                                  remat="none", caches=state.caches,
                                  scan=parallel.scan_layers)
    logits = logits_from(params, cfg, x[:, -1:], engine)
    return logits[:, 0], DecodeState(caches=new_caches,
                                     position=jnp.asarray(s, jnp.int32))


def decode_step(params: dict, token: jax.Array, cfg: ModelConfig,
                engine: EngineConfig, parallel: ParallelConfig,
                state: DecodeState) -> tuple[jax.Array, DecodeState]:
    """One decode step.  token: [B] (audio: [B, cb]) -> logits, new state."""
    b = token.shape[0]
    tok = token[:, None] if token.ndim == 1 else token[:, None, :]
    x = embed_tokens(params, cfg, tok)
    pos = positions_for(cfg, b, 1, offset=state.position)
    sin, cos = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta,
                           mrope_sections(cfg.resolved_head_dim) if cfg.rope == "mrope" else None)
    x, new_caches, _ = run_layers(params, x, cfg, engine, sin, cos,
                                  remat="none", caches=state.caches,
                                  scan=parallel.scan_layers)
    logits = logits_from(params, cfg, x, engine)
    return logits[:, 0], DecodeState(caches=new_caches,
                                     position=state.position + 1)
