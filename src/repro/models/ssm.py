"""Mamba2 (SSD -- state-space duality) blocks, chunked for MXU-friendliness.

The chunked SSD algorithm (Dao & Gu, 2024) decomposes the selective-scan
into per-chunk *matmuls* (intra-chunk quadratic term + inter-chunk state
recurrence), which is exactly the GEMM-shaped compute the RASA engine
accelerates -- see DESIGN.md §Arch-applicability.

Layer = in_proj -> short causal conv (x, B, C) -> SSD -> gated RMSNorm ->
out_proj.  Decode keeps (conv window, SSM state) per layer: O(1) per token,
which is why the ssm/hybrid archs run the long_500k shape.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..config import EngineConfig, ModelConfig
from .common import matmul
from .layers import rms_norm


class SSMState(NamedTuple):
    conv: jax.Array     # [B, d_conv-1, conv_channels]
    ssm: jax.Array      # [B, H, P, N]


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    d_inner, n_heads, _ = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv1d, window k.  xbc: [B, S, C]; w: [k, C].

    With `state` ([B, k-1, C], the trailing window of the previous tokens)
    this is the streaming/decode form; returns (out, new_state).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)            # [B, S+k-1, C]
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    out = jax.nn.silu(out + b[None, None, :])
    new_state = xp[:, -(k - 1):, :]
    return out, new_state


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                B: jax.Array, C: jax.Array, chunk: int,
                init_state: jax.Array | None = None,
                unroll: bool = False):
    """Chunked SSD as a checkpointed scan over chunks.

    x:  [b, s, h, p]   inputs per head
    dt: [b, s, h]      positive step sizes
    A:  [h]            negative decay rates
    B:  [b, s, g, n]   input projections (groups broadcast over heads)
    C:  [b, s, g, n]   output projections
    Returns y [b, s, h, p] and the final state [b, h, p, n].

    One chunk is processed at a time and the body is rematerialized in the
    backward pass -- materializing all [b, nc, h, q, q] intra-chunk score
    matrices at once costs 26 GiB/dev on the zamba2 train cell vs ~1 GiB
    this way (EXPERIMENTS.md §Perf).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    # per-chunk leading axis for the scan: [nc, b, q, ...]
    xc = x.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    Bc = B.reshape(b, nc, chunk, g, n).transpose(1, 0, 2, 3, 4)
    Cc = C.reshape(b, nc, chunk, g, n).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def chunk_body(state, inp):
        x_c, dt_c, B_c, C_c = inp              # [b,q,h,p],[b,q,h],[b,q,g,n]x2
        B_h = jnp.repeat(B_c, rep, axis=2)     # [b,q,h,n]
        C_h = jnp.repeat(C_c, rep, axis=2)
        dA = dt_c * A[None, None, :]           # [b,q,h] (negative)
        seg = jnp.cumsum(dA, axis=1)           # within-chunk cumsum
        # fold dt_j into x_j ONCE ([b,q,h,p]) instead of scaling the
        # [b,h,q,q] score matrix by dt_j -- algebraically identical,
        # removes the largest intermediate's extra pass (§Perf zamba2)
        xdt = (x_c.astype(jnp.float32)
               * dt_c[..., None]).astype(x.dtype)  # [b,q,h,p]

        # intra-chunk: scores[i,j] = C_i.B_j exp(seg_i - seg_j), i>=j
        cb = jnp.einsum("bihn,bjhn->bhij", C_h, B_h,
                        preferred_element_type=jnp.float32)
        segh = seg.transpose(0, 2, 1)          # [b,h,q]
        diff = segh[..., :, None] - segh[..., None, :]
        # mask the exponent BEFORE exp: no inf*0 NaNs in gradients
        diff = jnp.where(mask[None, None], diff, -1e30)
        w_ij = cb * jnp.exp(diff)
        y_intra = jnp.einsum("bhij,bjhp->bihp", w_ij.astype(x.dtype), xdt,
                             preferred_element_type=jnp.float32)

        # inter-chunk: y_i += C_i . state_prev * exp(seg_i)
        y_inter = jnp.einsum("bihn,bhpn,bih->bihp", C_h,
                             state.astype(x.dtype),
                             jnp.exp(seg).astype(x.dtype),
                             preferred_element_type=jnp.float32)

        # chunk state + recurrence
        last = seg[:, -1:, :]                  # [b,1,h]
        wj = jnp.exp(last - seg).astype(x.dtype)            # [b,q,h]
        st_c = jnp.einsum("bjhn,bjhp,bjh->bhpn", B_h, xdt, wj,
                          preferred_element_type=jnp.float32)
        decay = jnp.exp(last[:, 0, :])         # [b,h]
        new_state = state * decay[:, :, None, None] + st_c
        return new_state, (y_intra + y_inter).astype(x.dtype)

    init = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final_state, ys = jax.lax.scan(chunk_body, init, (xc, dtc, Bc, Cc),
                                   unroll=nc if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, final_state


def mamba2_block(p: dict, x: jax.Array, cfg: ModelConfig,
                 engine: EngineConfig,
                 state: SSMState | None = None) -> tuple[jax.Array, SSMState | None]:
    """Full Mamba2 residual branch.  Training (state=None): chunked SSD.
    Decode: single-token recurrent update (x is [B, 1, D])."""
    s_cfg = cfg.ssm
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    b, s, _ = x.shape
    hdim, nst, g = s_cfg.head_dim, s_cfg.d_state, s_cfg.n_groups

    zxbcdt = matmul(x, p["in_proj"], engine)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if state is None or s > 1:
        # training (state None) or prefill (state carried through chunks)
        conv_in = None if state is None else state.conv
        xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_in)
        x_in, B, C = jnp.split(xbc, [d_inner, d_inner + g * nst], axis=-1)
        xh = x_in.reshape(b, s, n_heads, hdim)
        Bh = B.reshape(b, s, g, nst)
        Ch = C.reshape(b, s, g, nst)
        chunk = min(s_cfg.chunk, s)
        assert s % chunk == 0, f"prefill length {s} % chunk {chunk} != 0"
        y, final = ssd_chunked(xh, dt, A, Bh, Ch, chunk,
                               None if state is None else state.ssm,
                               unroll=engine.unroll_ssd)
        new_state = (None if state is None
                     else SSMState(conv=conv_state, ssm=final))
    else:
        xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                       state.conv)
        x_in, B, C = jnp.split(xbc, [d_inner, d_inner + g * nst], axis=-1)
        xh = x_in.reshape(b, s, n_heads, hdim)
        Bh = jnp.repeat(B.reshape(b, s, g, nst), n_heads // g, axis=2)
        Ch = jnp.repeat(C.reshape(b, s, g, nst), n_heads // g, axis=2)
        # s == 1: recurrent update
        dA = jnp.exp(dt[:, 0] * A[None, :])                       # [B, H]
        st = (state.ssm * dA[:, :, None, None]
              + jnp.einsum("bhn,bhp,bh->bhpn", Bh[:, 0], xh[:, 0],
                           dt[:, 0], preferred_element_type=jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", Ch[:, 0], st.astype(x.dtype),
                       preferred_element_type=jnp.float32)
        y = y[:, None].astype(x.dtype).reshape(b, s, n_heads, hdim)
        new_state = SSMState(conv=conv_state, ssm=st.astype(jnp.float32))

    y = y + p["D_skip"].astype(x.dtype)[None, None, :, None] \
        * xh.astype(x.dtype)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["ssm_norm"], cfg.rms_eps)
    return matmul(y, p["out_proj"], engine), new_state


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    s = cfg.ssm
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_ch), jnp.dtype(cfg.dtype)),
        ssm=jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32))
