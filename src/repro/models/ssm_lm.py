"""Pure-SSM language model (mamba2-130m) and the Zamba2-style hybrid.

hybrid (zamba2): all layers are Mamba2 blocks; ONE shared attention+MLP
block (a single weight set) is applied after every ``attn_every`` Mamba
layers -- each application keeps its own KV cache.  (The real Zamba2
alternates two shared blocks and concatenates the original embedding into
the shared-block input; we implement the single-shared-block form and note
the simplification in DESIGN.md.)
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..config import EngineConfig, ModelConfig, ParallelConfig
from ..distributed.sharding import constrain
from .common import (KeyGen, chunked_cross_entropy, cross_entropy,
                     embed_init, he_init, matmul)
from .layers import KVCache, attention_block, mlp_block, rms_norm, rope_angles
from .ssm import SSMState, init_ssm_state, mamba2_block, ssm_dims
from .transformer import _remat, logits_from


# ------------------------------------------------------------------- params

def init_mamba_layer_params(cfg: ModelConfig, kg: KeyGen, dtype, n_layers: int) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    proj = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    L = n_layers
    return {
        "norm1": jnp.zeros((L, d), dtype),
        "in_proj": he_init(kg("in_proj"), (L, d, proj), dtype, fan_in=d),
        "conv_w": he_init(kg("conv_w"), (L, s.d_conv, conv_ch), dtype,
                          fan_in=s.d_conv),
        "conv_b": jnp.zeros((L, conv_ch), dtype),
        "dt_bias": jnp.zeros((L, n_heads), jnp.float32)
        + jnp.log(jnp.expm1(jnp.linspace(0.001, 0.1, n_heads))[None]).astype(jnp.float32),
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32))[None],
            (L, n_heads)).copy(),
        "D_skip": jnp.ones((L, n_heads), jnp.float32),
        "ssm_norm": jnp.zeros((L, d_inner), dtype),
        "out_proj": he_init(kg("out_proj"), (L, d_inner, d), dtype,
                            fan_in=d_inner),
    }


def init_shared_attn_params(cfg: ModelConfig, kg: KeyGen, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    p = {
        "norm1": jnp.zeros((d,), dtype),
        "wq": he_init(kg("s_wq"), (d, cfg.n_heads * hd), dtype, fan_in=d),
        "wk": he_init(kg("s_wk"), (d, cfg.n_kv_heads * hd), dtype, fan_in=d),
        "wv": he_init(kg("s_wv"), (d, cfg.n_kv_heads * hd), dtype, fan_in=d),
        "wo": he_init(kg("s_wo"), (cfg.n_heads * hd, d), dtype,
                      fan_in=cfg.n_heads * hd),
        "norm2": jnp.zeros((d,), dtype),
        "w_gate": he_init(kg("s_wg"), (d, cfg.d_ff), dtype, fan_in=d),
        "w_up": he_init(kg("s_wu"), (d, cfg.d_ff), dtype, fan_in=d),
        "w_down": he_init(kg("s_wd"), (cfg.d_ff, d), dtype, fan_in=cfg.d_ff),
    }
    return p


def init_params(cfg: ModelConfig, rng: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    kg = KeyGen(rng)
    params = {
        "embedding": embed_init(kg("embed"), (cfg.vocab, cfg.d_model), dtype),
        "layers": init_mamba_layer_params(cfg, kg, dtype, cfg.n_layers),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(kg("head"), (cfg.d_model, cfg.vocab),
                                    dtype, fan_in=cfg.d_model)
    if cfg.family == "hybrid":
        params["shared_attn"] = init_shared_attn_params(cfg, kg, dtype)
    return params


# ------------------------------------------------------------------ forward

class HybridState(NamedTuple):
    ssm: SSMState              # stacked [L, ...] leaves
    attn: KVCache              # stacked [n_apps, ...] leaves
    position: jax.Array


def _shared_block(params: dict, x, cfg, engine, sin, cos,
                  cache: Optional[KVCache]):
    sp = params["shared_attn"]
    h = rms_norm(x, sp["norm1"], cfg.rms_eps)
    attn_out, new_cache = attention_block(sp, h, cfg, engine, sin, cos, cache)
    x = constrain(x + attn_out, "btd")
    h = rms_norm(x, sp["norm2"], cfg.rms_eps)
    x = constrain(x + mlp_block(sp, h, cfg, engine), "btd")
    return x, new_cache


def n_shared_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.hybrid.attn_every if cfg.family == "hybrid" else 0


def run_backbone(params: dict, x: jax.Array, cfg: ModelConfig,
                 engine: EngineConfig, remat: str = "full",
                 state: Optional[HybridState] = None,
                 sin=None, cos=None, scan: bool = True):
    """Scan Mamba2 layers; hybrid: shared attention every attn_every layers.

    Training: state None.  Decode (x [B,1,D]): state carries per-layer SSM
    states + per-application KV caches.  scan=False unrolls python loops
    (reduced-depth roofline compiles).
    """
    L = cfg.n_layers

    if not scan:
        return _run_backbone_unrolled(params, x, cfg, engine, state, sin, cos)

    def mamba_body(carry, layer_in):
        h = carry
        if state is None:
            h2 = rms_norm(h, layer_in["norm1"], cfg.rms_eps)
            out, _ = mamba2_block(layer_in, h2, cfg, engine)
            return constrain(h + out, "btd"), None
        params_l, st_l = layer_in
        h2 = rms_norm(h, params_l["norm1"], cfg.rms_eps)
        out, new_st = mamba2_block(params_l, h2, cfg, engine, st_l)
        return constrain(h + out, "btd"), new_st

    if cfg.family == "ssm":
        if state is None:
            x, _ = jax.lax.scan(_remat(mamba_body, remat), x, params["layers"])
            return x, None
        x, new_ssm = jax.lax.scan(mamba_body, x, (params["layers"], state.ssm))
        return x, HybridState(ssm=new_ssm, attn=state.attn,
                              position=state.position + x.shape[1])

    # hybrid: groups of `attn_every` mamba layers + one shared attn block
    every = cfg.hybrid.attn_every
    n_groups = L // every
    assert L % every == 0

    def group_leaves(tree):
        return jax.tree.map(
            lambda a: a.reshape(n_groups, every, *a.shape[1:]), tree)

    grouped = group_leaves(params["layers"])

    if state is None:
        def group_body(carry, params_g):
            h = carry
            # nested remat: each mamba layer inside the (checkpointed)
            # group is itself checkpointed, otherwise the group backward
            # holds six layers' in_proj activations (~8 GiB/dev on the
            # zamba2 train cell; EXPERIMENTS.md §Perf)
            h, _ = jax.lax.scan(_remat(mamba_body, remat), h, params_g)
            h, _ = _shared_block(params, h, cfg, engine, sin, cos, None)
            return h, None
        x, _ = jax.lax.scan(_remat(group_body, remat), x, grouped)
        return x, None

    grouped_ssm = group_leaves(state.ssm)

    def group_body(carry, inp):
        h = carry
        params_g, ssm_g, cache_g = inp
        h, new_ssm_g = jax.lax.scan(mamba_body, h, (params_g, ssm_g))
        h, new_cache_g = _shared_block(params, h, cfg, engine, sin, cos,
                                       cache_g)
        return h, (new_ssm_g, new_cache_g)

    x, (new_ssm_g, new_caches) = jax.lax.scan(
        group_body, x, (grouped, grouped_ssm, state.attn))
    new_ssm = jax.tree.map(lambda a: a.reshape(L, *a.shape[2:]), new_ssm_g)
    return x, HybridState(ssm=new_ssm, attn=new_caches,
                          position=state.position + x.shape[1])


def _run_backbone_unrolled(params, x, cfg, engine, state, sin, cos):
    """Python-loop depth (roofline reduced-depth compiles)."""
    every = cfg.hybrid.attn_every if cfg.family == "hybrid" else cfg.n_layers

    def one_layer(h, i, st_l):
        params_l = jax.tree.map(lambda a: a[i], params["layers"])
        h2 = rms_norm(h, params_l["norm1"], cfg.rms_eps)
        out, new_st = mamba2_block(params_l, h2, cfg, engine, st_l)
        return constrain(h + out, "btd"), new_st

    new_ssm, new_caches = [], []
    for i in range(cfg.n_layers):
        st_l = (jax.tree.map(lambda a: a[i], state.ssm)
                if state is not None else None)
        x, new_st = one_layer(x, i, st_l)
        if state is not None:
            new_ssm.append(new_st)
        if cfg.family == "hybrid" and (i + 1) % every == 0:
            app = i // every
            cache_a = (jax.tree.map(lambda a: a[app], state.attn)
                       if state is not None else None)
            x, new_cache = _shared_block(params, x, cfg, engine, sin, cos,
                                         cache_a)
            if state is not None:
                new_caches.append(new_cache)
    if state is None:
        return x, None
    ssm_st = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_ssm)
              if new_ssm else state.ssm)
    attn_st = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
               if new_caches else state.attn)
    return x, HybridState(ssm=ssm_st, attn=attn_st,
                          position=state.position + x.shape[1])


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            engine: EngineConfig, parallel: ParallelConfig):
    tokens = batch["tokens"]
    x = constrain(params["embedding"][tokens], "btd")
    sin = cos = None
    if cfg.family == "hybrid":
        b, s = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        sin, cos = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
    x, _ = run_backbone(params, x, cfg, engine, remat=parallel.remat,
                        sin=sin, cos=cos, scan=parallel.scan_layers)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head_w = (params["embedding"].T if cfg.tie_embeddings
              else params["lm_head"])
    ce, n_valid = chunked_cross_entropy(x, head_w, batch["labels"],
                                        chunk=engine.ce_chunk)
    return ce, {"ce": ce, "aux_loss": 0.0, "n_valid": n_valid}


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=None) -> HybridState:
    dtype = dtype or jnp.dtype(cfg.dtype)
    s = cfg.ssm
    d_inner, n_heads, conv_ch = ssm_dims(cfg)
    L = cfg.n_layers
    ssm = SSMState(
        conv=jnp.zeros((L, batch, s.d_conv - 1, conv_ch), dtype),
        ssm=jnp.zeros((L, batch, n_heads, s.head_dim, s.d_state),
                      jnp.float32))
    apps = n_shared_apps(cfg)
    if apps:
        hd = cfg.resolved_head_dim
        shape = (apps, batch, cfg.n_kv_heads, max_seq, hd)
        attn = KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                       length=jnp.zeros((apps,), jnp.int32))
    else:
        attn = KVCache(k=jnp.zeros((0,)), v=jnp.zeros((0,)),
                       length=jnp.zeros((0,), jnp.int32))
    return HybridState(ssm=ssm, attn=attn, position=jnp.zeros((), jnp.int32))


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig,
            engine: EngineConfig, parallel: ParallelConfig,
            state: HybridState):
    b, s = tokens.shape
    x = constrain(params["embedding"][tokens], "btd")
    sin = cos = None
    if cfg.family == "hybrid":
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        sin, cos = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
    x, new_state = run_backbone(params, x, cfg, engine, state=state,
                                sin=sin, cos=cos, scan=parallel.scan_layers)
    logits = logits_from(params, cfg, x[:, -1:], engine)
    return logits[:, 0], new_state


def decode_step(params: dict, token: jax.Array, cfg: ModelConfig,
                engine: EngineConfig, parallel: ParallelConfig,
                state: HybridState):
    b = token.shape[0]
    x = params["embedding"][token[:, None]]
    sin = cos = None
    if cfg.family == "hybrid":
        pos = jnp.broadcast_to(state.position[None, None], (b, 1))
        sin, cos = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
    x, new_state = run_backbone(params, x, cfg, engine, state=state,
                                sin=sin, cos=cos, scan=parallel.scan_layers)
    logits = logits_from(params, cfg, x, engine)
    return logits[:, 0], new_state
