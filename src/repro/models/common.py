"""Shared model plumbing: the matrix-engine dispatch + init helpers.

Every GEMM in every model routes through :func:`matmul`, which selects the
engine per config -- ``xla`` (jnp.dot, used for dry-run/roofline since
Mosaic doesn't lower on CPU) or ``pallas_rasa`` (the RASA-scheduled Pallas
kernel from ``repro.kernels``, interpret-mode on CPU).  This is how the
paper's technique is a first-class feature of the framework.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import EngineConfig, ModelConfig
from ..kernels import GemmBlocks, rasa_matmul


def matmul(x: jax.Array, w: jax.Array, engine: EngineConfig | None = None,
           out_dtype=None) -> jax.Array:
    """x [..., K] @ w [K, N] with fp32 accumulation, cast to out_dtype
    (default: x.dtype)."""
    out_dtype = out_dtype or x.dtype
    if engine is not None and engine.kind == "pallas_rasa":
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        blocks = GemmBlocks(engine.block_m, engine.block_k, engine.block_n)
        out = rasa_matmul(x2, w, schedule=engine.schedule, blocks=blocks)
        return out.reshape(*lead, w.shape[-1]).astype(out_dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def he_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    scale = (2.0 / max(fan_in, 1)) ** 0.5
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Deterministic named key derivation (stable across processes --
    crc32, NOT python hash(), which is randomized per process and would
    break checkpoint-restore reproducibility)."""

    def __init__(self, root: jax.Array):
        self.root = root

    def __call__(self, name: str) -> jax.Array:
        import zlib
        return jax.random.fold_in(self.root, zlib.crc32(name.encode()))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_index: int = -100) -> tuple[jax.Array, jax.Array]:
    """Mean CE over valid labels (fp32).  logits [..., V], labels [...]."""
    logits = logits.astype(jnp.float32)
    valid = labels != ignore_index
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * valid
    n = jnp.maximum(valid.sum(), 1)
    return nll.sum() / n, n


def chunked_cross_entropy(x: jax.Array, head_w: jax.Array,
                          labels: jax.Array, *, chunk: int = 256,
                          ignore_index: int = -100,
                          logits_fn=None) -> tuple[jax.Array, jax.Array]:
    """CE of matmul(x, head_w) without materializing full-sequence logits.

    x: [B, S, D]; head_w: [D, V]; labels: [B, S] (or [B, S, cb] with
    logits_fn reshaping).  Scans over S-chunks with remat, so peak memory
    holds one [B, chunk, V] logits block instead of [B, S, V] -- the
    difference between 18.5 GiB/dev and ~7 GiB/dev on the 256k-vocab
    gemma train cells (EXPERIMENTS.md §Perf).
    """
    b, s = x.shape[0], x.shape[1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xs = x.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk, *labels.shape[2:]).transpose(1, 0, 2,
                                                                   *range(3, labels.ndim + 1))

    @jax.checkpoint
    def chunk_loss(x_c, l_c):
        logits = jnp.dot(x_c, head_w,
                         preferred_element_type=jnp.float32)
        if logits_fn is not None:
            logits = logits_fn(logits)
        valid = l_c != ignore_index
        safe = jnp.where(valid, l_c, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        return ((logz - ll) * valid).sum(), valid.sum()

    def body(carry, inp):
        tot, n = carry
        x_c, l_c = inp
        dt, dn = chunk_loss(x_c, l_c)
        return (tot + dt, n + dn), None

    (tot, n), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xs, ls))
    n = jnp.maximum(n, 1)
    return tot / n, n
