"""Core layers: norms, rotary embeddings (incl. M-RoPE), attention, MLPs.

All functions are pure; per-layer parameter dicts come in without the
stacked layer dim (transformer.py scans over it).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..config import EngineConfig, ModelConfig
from ..distributed.sharding import constrain
from .common import matmul

# --------------------------------------------------------------------- norms

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(x.dtype)


# --------------------------------------------------------------------- rope

def rope_angles(positions: jax.Array, head_dim: int, theta: float,
                sections: tuple[int, ...] | None = None) -> tuple[jax.Array, jax.Array]:
    """sin/cos tables.

    positions: [B, S] (standard) or [3, B, S] (M-RoPE: temporal/height/width
    streams).  With M-RoPE, the head_dim/2 frequency slots are split into
    ``sections`` (e.g. 16/24/24 for qwen2-vl), each driven by its own
    position stream -- text tokens pass identical t/h/w so M-RoPE reduces
    to standard RoPE for them.
    Returns sin, cos of shape [B, S, head_dim//2].
    """
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 2:            # standard
        ang = positions.astype(jnp.float32)[..., None] * freqs  # [B,S,half]
    else:                              # m-rope: [3, B, S]
        assert sections is not None and sum(sections) == half
        ang_streams = positions.astype(jnp.float32)[..., None] * freqs  # [3,B,S,half]
        parts = []
        start = 0
        for i, sec in enumerate(sections):
            parts.append(ang_streams[i, :, :, start:start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: [B, S, H, hd]; sin/cos: [B, S, hd//2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------- attention

def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             *, scale: float, q_chunk: int = 1024,
                             kv_chunk: int = 2048,
                             logit_softcap: float = 0.0) -> jax.Array:
    """Memory-efficient causal attention in pure jnp (flash-style online
    softmax over kv chunks, scanned over q chunks).  The XLA path for
    training/prefill; the Pallas kernel replaces it on real TPUs.

    q: [B, H, Sq, d], k/v: [B, H, Skv, d] with Skv == Sq (self-attention).
    """
    b, h, s, d = q.shape
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    assert s % q_chunk == 0 and s % kv_chunk == 0
    nq, nk = s // q_chunk, s // kv_chunk

    qs = q.reshape(b, h, nq, q_chunk, d).transpose(2, 0, 1, 3, 4)
    ks = k.reshape(b, h, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, h, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk
        qf = qblk.astype(jnp.float32) * scale

        # flash-style backward: recompute the [bq, bkv] probability block
        # instead of storing it -- without this, differentiating the scan
        # keeps every p block alive (8+ GiB/layer at 4k seq; EXPERIMENTS.md
        # §Perf memory iteration)
        @jax.checkpoint
        def kv_step(carry, kj_blk):
            m_p, l_p, acc = carry
            kj, kblk, vblk = kj_blk
            s_ij = jnp.einsum("bhqd,bhkd->bhqk", qf,
                              kblk.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            if logit_softcap:
                s_ij = logit_softcap * jnp.tanh(s_ij / logit_softcap)
            rows = qi * q_chunk + jax.lax.broadcasted_iota(
                jnp.int32, (q_chunk, kv_chunk), 0)
            cols = kj * kv_chunk + jax.lax.broadcasted_iota(
                jnp.int32, (q_chunk, kv_chunk), 1)
            s_ij = jnp.where(rows[None, None] >= cols[None, None], s_ij, -1e30)
            m_c = jnp.maximum(m_p, jnp.max(s_ij, axis=-1, keepdims=True))
            p = jnp.exp(s_ij - m_c)
            alpha = jnp.exp(m_p - m_c)
            l_c = l_p * alpha + p.sum(axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32),
                preferred_element_type=jnp.float32)
            return (m_c, l_c, acc), None

        init = (jnp.full((b, h, q_chunk, 1), -1e30, jnp.float32),
                jnp.zeros((b, h, q_chunk, 1), jnp.float32),
                jnp.zeros((b, h, q_chunk, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    return outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, d)


@dataclasses.dataclass
class KVCache:
    """Decode-time cache: k/v [B, Hkv, S_max, hd]; length = filled prefix."""
    k: jax.Array
    v: jax.Array
    length: jax.Array        # scalar int32

    def tree_flatten(self):
        return (self.k, self.v, self.length), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    KVCache, KVCache.tree_flatten, KVCache.tree_unflatten)


def gqa_expand(x: jax.Array, n_heads: int) -> jax.Array:
    """[B, Hkv, ...] -> [B, H, ...] by repeating kv groups."""
    hkv = x.shape[1]
    if hkv == n_heads:
        return x
    return jnp.repeat(x, n_heads // hkv, axis=1)


def attention_block(p: dict, x: jax.Array, cfg: ModelConfig,
                    engine: EngineConfig,
                    sin: jax.Array, cos: jax.Array,
                    cache: Optional[KVCache] = None) -> tuple[jax.Array, Optional[KVCache]]:
    """Pre-norm attention residual branch.

    Training/prefill: cache is None -> chunked causal attention over x.
    Decode: x is [B, 1, D]; cache holds the past -> returns updated cache.
    """
    b, s, d_model = x.shape
    hd = cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads

    q = matmul(x, p["wq"], engine).reshape(b, s, h, hd)
    k = matmul(x, p["wk"], engine).reshape(b, s, hkv, hd)
    v = matmul(x, p["wv"], engine).reshape(b, s, hkv, hd)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    if cfg.rope != "none":
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)

    q = q.transpose(0, 2, 1, 3)      # [B, H, S, hd]
    k = k.transpose(0, 2, 1, 3)      # [B, Hkv, S, hd]
    v = v.transpose(0, 2, 1, 3)
    scale = hd ** -0.5

    if cache is None or s > 1:
        # training, or prefill (cache filled from position 0; the chunked
        # kernel attends over exactly the causal prefix being written)
        if cache is not None:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), 0, axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), 0, axis=2)
            cache = KVCache(ck, cv, jnp.asarray(s, jnp.int32))
        # NOTE: q/k/v deliberately carry no explicit head constraint here --
        # XLA's propagation from the wq/wk/wv column sharding is strictly
        # better than forcing "bhsd" (measured: +7 GiB/dev from involuntary
        # remat copies when heads < model axis; EXPERIMENTS.md §Perf).
        kf = gqa_expand(k, h)
        vf = gqa_expand(v, h)
        out = chunked_causal_attention(q, kf, vf, scale=scale,
                                       q_chunk=engine.attn_q_chunk,
                                       kv_chunk=engine.attn_kv_chunk,
                                       logit_softcap=cfg.logit_softcap)
    else:
        # single-token decode: append to cache, attend over valid prefix.
        # GQA without cache expansion: queries grouped per kv head --
        # expanding + f32-casting a 32k cache costs ~6x the cache itself
        # (22 GiB/dev on the grok decode cell; EXPERIMENTS.md §Perf).
        pos = cache.length
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), pos, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), pos, axis=2)
        cache = KVCache(ck, cv, pos + s)
        group = h // hkv
        qg = q.reshape(b, hkv, group * s, hd).astype(jnp.float32) * scale
        logits = jnp.einsum("bhqd,bhkd->bhqk", qg, ck.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        smax = ck.shape[2]
        # queries are (group-major) the s new positions repeated per group
        qpos = pos + jnp.tile(jnp.arange(s), group)
        mask = jnp.arange(smax)[None, None, None, :] <= qpos[None, None, :, None]
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", probs,
                         cv.astype(jnp.float32)).astype(x.dtype)
        out = out.reshape(b, h, s, hd)

    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return matmul(out, p["wo"], engine), cache


# ----------------------------------------------------------------------- mlp

def mlp_block(p: dict, x: jax.Array, cfg: ModelConfig,
              engine: EngineConfig) -> jax.Array:
    act = cfg.act
    if act in ("swiglu", "geglu"):
        if "w_gate_up" in p:
            # fused gate+up: one GEMM, x read once (WL-skip analogue; §Perf)
            gu = jnp.einsum("bsd,dgf->bsgf", x, p["w_gate_up"],
                            preferred_element_type=jnp.float32).astype(x.dtype)
            g, u = gu[:, :, 0], gu[:, :, 1]
        else:
            g = matmul(x, p["w_gate"], engine)
            u = matmul(x, p["w_up"], engine)
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = constrain(g * u, "btf")
    else:
        u = matmul(x, p["w_up"], engine)
        if act == "relu2":               # nemotron squared-ReLU
            u = jnp.square(jax.nn.relu(u))
        else:
            u = jax.nn.gelu(u, approximate=True)
        h = constrain(u, "btf")
    return matmul(h, p["w_down"], engine)
