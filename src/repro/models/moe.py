"""Mixture-of-Experts FFN: top-k routing, grouped sort-based dispatch.

Dispatch happens independently inside ``dispatch_groups`` token groups
(group dim sharded over DP), with per-group expert capacity -- the way EP
is deployed in practice (per-device dispatch).  This keeps every sort /
scatter / gather *batched along a sharded leading dim*, which GSPMD
partitions cleanly; a single global sort instead forces involuntary
replication of the [E, C, D] buffers (measured 227 GiB/dev on the grok
train cell vs 9 GiB grouped -- EXPERIMENTS.md §Perf).

Capacity-bounded (capacity_factor slack; overflow tokens keep their
residual path).  Router gradients flow through the combine weights; a
Switch-style load-balancing auxiliary loss is returned to the caller.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import EngineConfig, ModelConfig
from ..distributed.sharding import constrain
from .common import matmul


def _group_count(t: int, requested: int) -> int:
    """Largest divisor of t that is <= requested (decode steps have tiny t)."""
    g = min(requested, t)
    while t % g:
        g -= 1
    return g


def moe_block(p: dict, x: jax.Array, cfg: ModelConfig,
              engine: EngineConfig) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    moe = cfg.moe
    assert moe is not None
    b, s, d = x.shape
    t = b * s
    e, k = moe.n_experts, moe.top_k
    g = _group_count(t, getattr(moe, "dispatch_groups", 16))
    tg = t // g
    cap = max(int(tg * k / e * moe.capacity_factor) + 1, 1)

    xf = x.reshape(g, tg, d)
    # 2D dot (batched bf16->f32 einsums don't execute on the CPU thunk
    # runtime; the 2D form works everywhere)
    logits = jnp.dot(xf.reshape(t, d), p["router"].astype(x.dtype),
                     preferred_element_type=jnp.float32).reshape(g, tg, e)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                       # [G,Tg,k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch) ----
    frac_routed = jnp.mean(
        jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=(0, 1, 2))
    aux = e * jnp.sum(frac_routed * probs.mean((0, 1))) * moe.aux_loss_weight

    # ---- grouped sort-based dispatch (all ops batched over G) ----
    e_flat = top_i.reshape(g, tg * k)                            # [G, Tg*k]
    w_flat = top_w.reshape(g, tg * k)
    t_flat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), k)[None], (g, tg * k))
    order = jnp.argsort(e_flat, axis=-1)                         # stable
    se = jnp.take_along_axis(e_flat, order, axis=-1)
    st = jnp.take_along_axis(t_flat, order, axis=-1)
    sw = jnp.take_along_axis(w_flat, order, axis=-1)
    counts = (e_flat[..., None] == jnp.arange(e)[None, None]).sum(1)  # [G,E]
    starts = jnp.cumsum(counts, axis=-1) - counts
    slot = jnp.arange(tg * k)[None] - jnp.take_along_axis(starts, se, -1)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, 0)

    gathered_in = jnp.take_along_axis(xf, st[..., None], axis=1)  # [G,Tg*k,D]
    gathered_in = jnp.where(keep[..., None], gathered_in, 0)

    def scatter_one(buf_g, se_g, slot_g, val_g):
        return buf_g.at[se_g, slot_g].add(val_g, mode="drop")

    buf = jax.vmap(scatter_one)(
        jnp.zeros((g, e, cap, d), x.dtype), se, slot_c,
        gathered_in.astype(x.dtype))

    # ---- expert FFNs ----
    # [G, E, C, D] -> [E, G*C, D]: expert-major batched matmul (the one
    # batched-dot form the CPU runtime executes); G*C stays group-major so
    # the DP sharding of the capacity dim is preserved.
    buf_e = constrain(
        buf.transpose(1, 0, 2, 3).reshape(e, g * cap, d), "ecd")
    if "experts_w_gate_up" in p:
        # fused: one GEMM reads buf_e once (WL-skip analogue; §Perf)
        w = p["experts_w_gate_up"]          # [E, D, 2, Fe]
        gu = jnp.einsum("ecd,edgf->ecgf", buf_e,
                        w, preferred_element_type=jnp.float32).astype(x.dtype)
        gate, up = gu[:, :, 0], gu[:, :, 1]
    else:
        gate = jnp.einsum("ecd,edf->ecf", buf_e, p["experts_w_gate"],
                          preferred_element_type=jnp.float32).astype(x.dtype)
        up = jnp.einsum("ecd,edf->ecf", buf_e, p["experts_w_up"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    inner = constrain(jax.nn.silu(gate) * up, "ecf")
    out_e = jnp.einsum("ecf,efd->ecd", inner, p["experts_w_down"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    out_buf = out_e.reshape(e, g, cap, d).transpose(1, 0, 2, 3)

    # ---- combine ----
    def gather_one(out_g, se_g, slot_g):
        return out_g[se_g, slot_g]

    back = jax.vmap(gather_one)(out_buf, se, slot_c)             # [G,Tg*k,D]
    contrib = back * (sw * keep).astype(x.dtype)[..., None]

    def combine_one(y_g, st_g, c_g):
        return y_g.at[st_g].add(c_g)

    y = jax.vmap(combine_one)(
        jnp.zeros((g, tg, d), x.dtype), st, contrib)
    return y.reshape(b, s, d), aux
