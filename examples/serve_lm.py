"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma-2b --batch 4
"""

import argparse
import sys
sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ServeSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    session = ServeSession(api, params,
                           max_seq=args.prompt_len + args.steps + 8)

    rng = np.random.default_rng(0)
    if cfg.model.family == "audio":
        prompts = jnp.asarray(rng.integers(
            0, cfg.model.vocab,
            (args.batch, args.prompt_len, cfg.model.n_codebooks)), jnp.int32)
    else:
        prompts = jnp.asarray(rng.integers(
            0, cfg.model.vocab, (args.batch, args.prompt_len)), jnp.int32)

    t0 = time.perf_counter()
    out = session.generate(prompts, args.steps)
    dt = time.perf_counter() - t0
    print(f"decoded {args.batch} x {args.steps} tokens in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s)")
    print("first sequence:", np.asarray(out)[0][:12].tolist())


if __name__ == "__main__":
    main()
