"""Explore the RASA design space beyond the paper.

1. Register-allocation policies: WLBP hit rate vs policy (the
   "register-aware" lever the paper fixes at Algorithm 1's 2x2 block).
2. AMX-tilecfg exact edge tiles (beyond-paper FF shortening).
3. Load-latency sensitivity (where the engine becomes memory-bound).

    PYTHONPATH=src python examples/rasa_design_space.py
"""

import sys
sys.path.insert(0, "src")

import dataclasses

from repro.core import (GemmSpec, RegPolicy, TABLE_I, get_design,
                        normalized_runtime, simulate, stream_stats)


def main():
    spec = TABLE_I["BERT-1"]

    print("== register policy design space (RASA-WLBP on BERT-1) ==")
    policies = {
        "alg1 2x2 (paper)": RegPolicy(mc=2, nc=2, a_regs=2, b_regs=2),
        "tall 4x1": RegPolicy(mc=4, nc=1, a_regs=2, b_regs=1),
        "max-reuse 5x1": RegPolicy(mc=5, nc=1, a_regs=2, b_regs=1),
        "wide 1x4": RegPolicy(mc=1, nc=4, a_regs=1, b_regs=2),
        "reuse-hostile": RegPolicy(mc=2, nc=2, a_regs=2, b_regs=2,
                                   mm_order="m_outer"),
    }
    for name, pol in policies.items():
        stats = stream_stats(spec, pol)
        r = normalized_runtime(spec, "RASA-WLBP", pol)
        print(f"  {name:20s} wlbp_rate={stats['wlbp_rate']:.2f} "
              f"norm_runtime={r:.3f}")

    print("\n== tilecfg exact tiles (batch 3 FC layer) ==")
    small = GemmSpec("fc-b3", 3, 1024, 1024)
    padded = simulate(small, "RASA-DMDB-WLS", RegPolicy())
    exact = simulate(small, "RASA-DMDB-WLS", RegPolicy(pad_tiles=False))
    print(f"  padded tiles: {padded.cycles:.0f} cycles; "
          f"exact tiles: {exact.cycles:.0f} cycles "
          f"({1 - exact.cycles / padded.cycles:.1%} faster)")

    print("\n== load-latency sensitivity (RASA-DMDB-WLS, DLRM-2) ==")
    for lat in (2, 5, 10, 20, 40, 80):
        cfg = dataclasses.replace(get_design("RASA-DMDB-WLS"),
                                  load_latency=lat)
        rep = simulate(TABLE_I["DLRM-2"], cfg)
        print(f"  load_latency={lat:3d} engine cycles -> "
              f"util={rep.utilization:.1%}")


if __name__ == "__main__":
    main()
