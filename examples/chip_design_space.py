"""Chip-level design-space walkthrough: from one RASA engine to a CMP.

Three questions a chip architect would ask before committing to a RASA CMP,
answered with the :mod:`repro.multicore` subsystem:

  1. How should one GEMM be split across cores?   (partitioner comparison)
  2. How much memory bandwidth does the chip need? (bandwidth sweep)
  3. How should a model's layers be placed?        (scheduler comparison)

Run:  python examples/chip_design_space.py
"""

import math
import sys

sys.path.insert(0, "src")

from repro.core import GemmSpec, TABLE_I
from repro.multicore import ChipConfig, simulate_chip

SPEC = TABLE_I["BERT-1"]


def partitioner_comparison() -> None:
    print(f"== 1. Partitioning {SPEC.name} ({SPEC.M}x{SPEC.K}x{SPEC.N}) "
          "across 16 cores (RASA-DMDB-WLS, 256 B/cyc) ==")
    for part in ("m_split", "n_split", "block2d"):
        rep = simulate_chip(SPEC, ChipConfig(n_cores=16), partition=part)
        print(f"  {part:<9} cycles={rep.cycles:>9.0f}  eff={rep.efficiency:.3f}"
              f"  bw-stall={rep.bw_stall_share:.1%}")
    print("  -> m_split re-streams all of B on every core; the 4x4 block-"
          "cyclic grid\n     loads each B panel on only 4 cores and wins "
          "once bandwidth binds.\n")


def bandwidth_sweep() -> None:
    print("== 2. Bandwidth needed for 8 cores of RASA-DMDB-WLS ==")
    for bw in (64.0, 128.0, 256.0, 512.0, 1024.0, math.inf):
        rep = simulate_chip(SPEC, ChipConfig(n_cores=8, bw_bytes_per_cycle=bw),
                            partition="block2d")
        label = "inf" if math.isinf(bw) else f"{bw:.0f}"
        print(f"  {label:>5} B/cyc  speedup={rep.speedup:5.2f}"
              f"  eff={rep.efficiency:.3f}  bw-stall={rep.bw_stall_share:.1%}")
    print("  -> eight RASA-DMDB-WLS cores need ~512 B/cyc (64 per core) to "
          "scale;\n     the ~6x per-core engine speedup multiplies the "
          "chip's bandwidth\n     appetite by the same factor -- BASE cores "
          "get by on a sixth of that.\n")


def scheduler_comparison() -> None:
    wl = [TABLE_I["DLRM-2"], TABLE_I["BERT-1"], TABLE_I["DLRM-2"],
          TABLE_I["BERT-1"], TABLE_I["DLRM-2"], TABLE_I["DLRM-2"]]
    print("== 3. Placing a 6-layer workload on 4 cores (RASA-WLBP) ==")
    for sched in ("round_robin", "work_queue", "lpt", "gang"):
        rep = simulate_chip(wl, ChipConfig(n_cores=4, design="RASA-WLBP"),
                            scheduler=sched)
        lens = "/".join(str(len(g)) for g in rep.per_core_gemms)
        print(f"  {sched:<12} makespan={rep.cycles:>9.0f}"
              f"  speedup={rep.speedup:.2f}  gemms-per-core={lens}")
    print("  -> round-robin is blind to the 16x size skew between BERT-1 "
          "and DLRM-2;\n     the dynamic queue fills the gaps.")


if __name__ == "__main__":
    partitioner_comparison()
    bandwidth_sweep()
    scheduler_comparison()
