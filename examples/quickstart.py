"""Quickstart: the paper in five minutes.

1. Reproduce the RASA cycle model's headline numbers (L=95, 16/95).
2. Run a GEMM through the functional RASA engine and the Pallas kernel.
3. Train a tiny LM for a few steps with the framework.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
sys.path.insert(0, "src")

import numpy as np
import jax


def main():
    # --- 1. the paper's numbers -------------------------------------------
    from repro.core import (TABLE_I, get_design, normalized_runtime,
                            simulate)
    base = get_design("BASE")
    print(f"L_baseline = {base.serial_latency(16)} cycles (paper: 95)")
    for design in ("RASA-PIPE", "RASA-WLBP", "RASA-DMDB-WLS"):
        r = normalized_runtime(TABLE_I["DLRM-2"], design)
        print(f"{design:16s} normalized runtime on DLRM-2: {r:.3f}")
    rep = simulate(TABLE_I["DLRM-2"], "RASA-DMDB-WLS")
    print(f"RASA-DMDB-WLS utilization: {rep.utilization:.1%} "
          f"(BASE: {simulate(TABLE_I['DLRM-2'], 'BASE').utilization:.1%})")

    # --- 2. numerics: functional engine == Pallas kernel == oracle --------
    from repro.core.engine import reference_gemm, run_gemm
    from repro.kernels import GemmBlocks, rasa_matmul
    rng = np.random.default_rng(0)
    a = rng.normal(size=(64, 96)).astype(np.float32)
    b = rng.normal(size=(96, 48)).astype(np.float32)
    c = np.zeros((64, 48), np.float32)
    import jax.numpy as jnp
    cpu_engine = run_gemm(a, b, c)
    kernel = np.asarray(rasa_matmul(a.astype(jnp.bfloat16),
                                    b.astype(jnp.bfloat16),
                                    schedule="wlbp",
                                    blocks=GemmBlocks(128, 128, 128)))
    oracle = reference_gemm(a, b, c)
    print(f"functional-engine max err: {np.abs(cpu_engine - oracle).max():.2e}")
    print(f"pallas-kernel    max err: {np.abs(kernel - oracle).max():.2e}")

    # --- 3. train a tiny model --------------------------------------------
    from repro.configs import get_config
    from repro.data import SyntheticLMDataset
    from repro.models import build_model
    from repro.training import init_train_state
    from repro.training.step import build_train_step
    cfg = get_config("qwen3-1.7b", smoke=True)
    api = build_model(cfg)
    data = SyntheticLMDataset(cfg.model, seq_len=32, global_batch=4)
    state = init_train_state(api, jax.random.key(0))
    step = jax.jit(build_train_step(api), donate_argnums=(0,))
    for s in range(10):
        state, metrics = step(state, data.batch(s))
        if s % 3 == 0:
            print(f"step {s}: loss {float(metrics['loss']):.3f}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
