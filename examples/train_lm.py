"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpointing, fault tolerance, and the production train step.

    PYTHONPATH=src python examples/train_lm.py --steps 200

(~100M params: mamba2-130m at full config is CPU-trainable at short seq;
use --arch to pick any other architecture's smoke config.)
"""

import argparse
import sys
sys.path.insert(0, "src")

import dataclasses
import jax

from repro.config import TrainConfig
from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models import build_model
from repro.training import LoopConfig, TrainLoop, init_train_state
from repro.training.step import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--full", action="store_true",
                    help="full config (default: 100M-scale = mamba2-130m full)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # mamba2-130m's FULL config is ~130M params -- the "train a ~100M model
    # for a few hundred steps" driver; other archs default to smoke configs.
    smoke = not (args.full or args.arch == "mamba2-130m")
    cfg = get_config(args.arch, smoke=smoke)
    cfg = dataclasses.replace(cfg, train=TrainConfig(
        global_batch=args.batch, seq_len=args.seq, lr=3e-4,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 1)))
    print(f"arch={cfg.model.name} params~{cfg.model.param_count()/1e6:.0f}M")

    api = build_model(cfg)
    data = SyntheticLMDataset(cfg.model, seq_len=args.seq,
                              global_batch=args.batch, seed=0)
    state = init_train_state(api, jax.random.key(0))
    step_fn = jax.jit(build_train_step(api), donate_argnums=(0,))

    loop = TrainLoop(
        step_fn=step_fn, state=state, batch_fn=data.batch,
        cfg=LoopConfig(total_steps=args.steps, checkpoint_every=50,
                       checkpoint_dir=args.ckpt, handle_sigterm=True))
    loop.run()
    losses = [m["loss"] for m in loop.metrics_history]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")


if __name__ == "__main__":
    main()
